"""SQL front-end for hybrid semantic queries (paper §5 'Parsing and
binding').

Supports the paper's surface syntax:

    SELECT b.title, r.text
    FROM books b JOIN reviews r ON b.book_id = r.book_id
    WHERE SEMANTIC('{b.description} is about AI?')
      AND SEMANTIC('{r.text} is a positive review?')
      AND r.rating >= 3;

    SELECT b.title, SEMANTIC_INT('Rate {r.text} sentiment 1-5') AS score
    FROM books b JOIN reviews r ON b.id = r.book_id
    WHERE score >= 4;

Subset: SELECT list (columns, SEMANTIC_INT/FLOAT/TEXT projections with
AS), FROM with aliases, INNER/CROSS JOIN chains with equi ON, conjunctive
WHERE (comparisons, BETWEEN, IN, SEMANTIC()), ORDER BY, LIMIT. WHERE
clauses are split into minimal units so each semantic predicate becomes an
independently placeable SF (paper §5); alias-qualified columns inside
SEMANTIC templates are rebound to base-table names so ``ref(SF)`` is
correct. The emitted tree is the *unoptimized* plan — run it through
``repro.core.optimize`` exactly like builder-constructed plans.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional

from .builder import Q
from .plan import BoolOp, Cmp, Col, Expr, Node

_TOKEN_RE = re.compile(
    r"""
    (?P<string>'(?:[^']|'')*')
  | (?P<number>\d+\.\d+|\d+)
  | (?P<ident>[A-Za-z_][\w]*(?:\.[A-Za-z_][\w]*)?)
  | (?P<op><=|>=|<>|!=|=|<|>|\(|\)|,|\*|;)
  | (?P<ws>\s+)
    """,
    re.VERBOSE,
)

KEYWORDS = {
    "select", "from", "join", "cross", "inner", "on", "where", "and",
    "between", "in", "order", "by", "desc", "asc", "limit", "as",
    "semantic", "semantic_int", "semantic_float", "semantic_text",
    "group", "having", "not",
}


@dataclass
class Tok:
    kind: str  # string | number | ident | op | kw
    text: str


def tokenize(sql: str) -> list[Tok]:
    out: list[Tok] = []
    pos = 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            raise SQLError(f"cannot tokenize at: {sql[pos:pos+24]!r}")
        pos = m.end()
        if m.lastgroup == "ws":
            continue
        kind = m.lastgroup
        text = m.group()
        if kind == "ident" and text.lower() in KEYWORDS:
            out.append(Tok("kw", text.lower()))
        else:
            out.append(Tok(kind, text))
    return out


class SQLError(ValueError):
    pass


@dataclass
class _SemProj:
    phi: str
    out_name: str
    dtype: str


class Parser:
    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0

    # -- token helpers -----------------------------------------------------
    def peek(self, k: int = 0) -> Optional[Tok]:
        j = self.i + k
        return self.toks[j] if j < len(self.toks) else None

    def next(self) -> Tok:
        t = self.peek()
        if t is None:
            raise SQLError("unexpected end of query")
        self.i += 1
        return t

    def accept(self, kind: str, text: Optional[str] = None) -> Optional[Tok]:
        t = self.peek()
        if t and t.kind == kind and (text is None or t.text == text):
            self.i += 1
            return t
        return None

    def expect(self, kind: str, text: Optional[str] = None) -> Tok:
        t = self.accept(kind, text)
        if t is None:
            got = self.peek()
            raise SQLError(f"expected {text or kind}, got "
                           f"{got.text if got else 'EOF'}")
        return t

    # -- grammar -----------------------------------------------------------
    def parse(self) -> Node:
        self.expect("kw", "select")
        select_items = self._select_list()
        self.expect("kw", "from")
        q, aliases = self._from_clause()

        # rebind helper: alias.col -> table.col
        def rebind(name: str) -> str:
            if "." in name:
                a, c = name.split(".", 1)
                return f"{aliases.get(a, a)}.{c}"
            return name

        sem_projs: list[_SemProj] = []
        out_cols: list[str] = []
        for item in select_items:
            if isinstance(item, _SemProj):
                item.phi = self._rebind_template(item.phi, aliases)
                sem_projs.append(item)
                out_cols.append(f"sp.{item.out_name}")
            else:
                out_cols.append(rebind(item))

        for sp in sem_projs:
            q = q.sem_project(sp.phi, f"sp.{sp.out_name}", dtype=sp.dtype)

        if self.accept("kw", "where"):
            for unit in self._where_units():
                kind, payload = unit
                if kind == "semantic":
                    q = q.sem_filter(self._rebind_template(payload, aliases))
                else:
                    q = q.where(self._rebind_expr(payload, aliases,
                                                  sem_projs))

        if self.accept("kw", "group"):
            raise SQLError("GROUP BY: use the builder API (Q.group_by)")
        order = []
        if self.accept("kw", "order"):
            self.expect("kw", "by")
            while True:
                col = rebind(self.expect("ident").text)
                desc = bool(self.accept("kw", "desc"))
                if not desc:
                    self.accept("kw", "asc")
                order.append((col, desc))
                if not self.accept("op", ","):
                    break
            q = q.order_by(*order)
        if self.accept("kw", "limit"):
            q = q.limit(int(self.expect("number").text))
        self.accept("op", ";")
        if self.peek() is not None:
            raise SQLError(f"trailing tokens at {self.peek().text!r}")
        return q.select(*out_cols).build()

    def _select_list(self):
        items = []
        while True:
            t = self.peek()
            if t.kind == "kw" and t.text.startswith("semantic_"):
                self.next()
                dtype = {"semantic_int": "int", "semantic_float": "float",
                         "semantic_text": "text"}[t.text]
                self.expect("op", "(")
                phi = self._string()
                self.expect("op", ")")
                self.expect("kw", "as")
                name = self.expect("ident").text
                items.append(_SemProj(phi=phi, out_name=name, dtype=dtype))
            else:
                items.append(self.expect("ident").text)
            if not self.accept("op", ","):
                return items

    def _from_clause(self):
        aliases: dict[str, str] = {}

        def table_ref():
            name = self.expect("ident").text
            alias = name
            t = self.peek()
            if t and t.kind == "ident":
                alias = self.next().text
            aliases[alias] = name
            return Q.scan(name), alias

        q, _ = table_ref()
        while True:
            if self.accept("kw", "cross"):
                self.expect("kw", "join")
                rhs, _ = table_ref()
                q = q.cross(rhs)
            elif self.accept("kw", "inner") or (
                    self.peek() and self.peek().kind == "kw"
                    and self.peek().text == "join"):
                self.accept("kw", "join") or self.expect("kw", "join")
                rhs, _ = table_ref()
                self.expect("kw", "on")
                lk = self.expect("ident").text
                self.expect("op", "=")
                rk = self.expect("ident").text
                lk, rk = (self._q(lk, aliases), self._q(rk, aliases))
                q = q.join(rhs, lk, rk)
            else:
                return q, aliases

    @staticmethod
    def _q(name: str, aliases: dict) -> str:
        a, c = name.split(".", 1)
        return f"{aliases.get(a, a)}.{c}"

    def _string(self) -> str:
        return self.expect("string").text[1:-1].replace("''", "'")

    def _where_units(self):
        """conjunctive units: ('semantic', phi) | ('rel', raw_cmp_tuple)."""
        units = []
        while True:
            if self.accept("kw", "semantic"):
                self.expect("op", "(")
                units.append(("semantic", self._string()))
                self.expect("op", ")")
            else:
                units.append(("rel", self._comparison()))
            if not self.accept("kw", "and"):
                return units

    def _comparison(self):
        neg = bool(self.accept("kw", "not"))
        lhs = self.expect("ident").text
        if self.accept("kw", "between"):
            lo = self._value()
            self.expect("kw", "and")
            hi = self._value()
            return ("between", lhs, (lo, hi), neg)
        if self.accept("kw", "in"):
            self.expect("op", "(")
            vals = [self._value()]
            while self.accept("op", ","):
                vals.append(self._value())
            self.expect("op", ")")
            return ("in", lhs, tuple(vals), neg)
        op = self.expect("op").text
        op = {"=": "==", "<>": "!="}.get(op, op)
        rhs = self._value()
        return (op, lhs, rhs, neg)

    def _value(self):
        t = self.next()
        if t.kind == "number":
            return float(t.text) if "." in t.text else int(t.text)
        if t.kind == "string":
            return t.text[1:-1]
        if t.kind == "ident":
            return Col(t.text)  # column-to-column comparison
        raise SQLError(f"bad value {t.text!r}")

    # -- rebinding -----------------------------------------------------------
    @staticmethod
    def _rebind_template(phi: str, aliases: dict) -> str:
        def sub(m):
            a, c = m.group(1).split(".", 1)
            return "{" + f"{aliases.get(a, a)}.{c}" + "}"

        return re.sub(r"\{([A-Za-z_]\w*\.[A-Za-z_]\w*)\}", sub, phi)

    def _rebind_expr(self, raw, aliases: dict,
                     sem_projs: list[_SemProj]) -> Expr:
        op, lhs, rhs, neg = raw
        sp_names = {sp.out_name for sp in sem_projs}
        if "." in lhs:
            a, c = lhs.split(".", 1)
            lhs_q = f"{aliases.get(a, a)}.{c}"
        elif lhs in sp_names:
            lhs_q = f"sp.{lhs}"  # reference to a SEMANTIC_* projection
        else:
            raise SQLError(f"unqualified column {lhs!r}")
        if isinstance(rhs, Col) and "." in rhs.name:
            a, c = rhs.name.split(".", 1)
            rhs = Col(f"{aliases.get(a, a)}.{c}")
        e: Expr = Cmp(op, Col(lhs_q), rhs)
        if neg:
            e = BoolOp("not", (e,))
        return e


def parse_sql(sql: str) -> Node:
    """Parse a hybrid semantic SQL query into an (unoptimized) plan tree."""
    return Parser(sql).parse()
