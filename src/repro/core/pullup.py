"""Algorithm 1 (paper §4.1): greedy semantic-filter pull-up.

Repeatedly swaps each SF with its parent while the parent is not the root,
not a blocking operator, and not another semantic operator. Projections
crossed on the way up are widened with the SF's referenced columns so the
predicate stays evaluable (Alg. 1 lines 7-8). Terminates in O(n²·d)
(Thm 4.2).
"""
from __future__ import annotations

from .plan import (
    Catalog,
    Node,
    Project,
    SemanticFilter,
    swap_with_parent,
)


def pull_up_semantic_filters(root: Node, catalog: Catalog) -> Node:
    changed = True
    while changed:
        changed = False
        for sf in [n for n in root.walk() if isinstance(n, SemanticFilter)]:
            p = root.parent_of(sf)
            if p is None:
                continue  # sf is root (or detached)
            gp = root.parent_of(p)
            if gp is None:
                # p is the root: Alg.1 line 6 requires p != root
                continue
            if p.is_blocking or p.is_semantic:
                continue
            if isinstance(p, Project):
                for c in sf.ref_cols:
                    if c not in p.cols:
                        p.cols.append(c)
            root = swap_with_parent(root, sf)
            changed = True
    return root
