"""Train a small LM (~13M params, olmoe-family MoE) to BE the semantic
backend: it learns to answer the benchmark's YES/NO predicates from
labelled prompts, then gets evaluated on held-out rows.

    PYTHONPATH=src python examples/train_backend.py --steps 300

This is the training half of the end-to-end story (the paper's ℳ);
examples/serve_semantic_queries.py serves the checkpoint inside real
hybrid query plans.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny
from repro.data import make_ecommerce
from repro.models import init_params
from repro.sharding import ShardingPolicy
from repro.training.checkpoint import CheckpointManager
from repro.training.data import HashTokenizer, PromptStream
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_step import build_train_step


def backend_config():
    # a slightly larger "tiny": enough capacity to learn the predicates
    return get_tiny("olmoe-1b-7b").replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, moe_d_ff=256, vocab_size=4096, name="backend-13m")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--ckpt-dir", default="artifacts/backend_ckpt")
    args = ap.parse_args(argv)

    cfg = backend_config()
    policy = ShardingPolicy.single()
    db = make_ecommerce(seed=4)
    tok = HashTokenizer(cfg.vocab_size)
    stream = PromptStream(db=db, tokenizer=tok, batch_size=args.batch,
                          seq_len=args.seq, seed=0)
    print(f"[backend] {len(stream)} labelled prompts, "
          f"model={cfg.name}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)
    state = init_state(params, opt_cfg)
    step_fn = jax.jit(build_train_step(cfg, policy, opt_cfg, remat=None),
                      donate_argnums=(0, 1))

    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = stream[step]
        params, state, m = step_fn(params, state,
                                   {"tokens": jnp.asarray(batch["tokens"])})
        if (step + 1) % 50 == 0:
            print(f"[backend] step {step+1} loss={float(m['loss']):.4f} "
                  f"({(time.perf_counter()-t0)/(step+1):.2f}s/step)")

    # evaluate: does argmax at the SEP position produce the right label?
    correct = total = 0
    for s in range(5):
        batch = stream[10_000 + s]  # unseen step indices
        toks = jnp.asarray(batch["tokens"])
        from repro.models import forward

        logits, _, _ = forward(cfg, policy, params, {"tokens": toks})
        for i in range(toks.shape[0]):
            row = np.asarray(toks[i])
            sep_pos = int(np.nonzero(row == tok.SEP)[0][0])
            pred = int(jnp.argmax(logits[i, sep_pos]))
            total += 1
            correct += int(pred == int(batch["labels"][i]))
    acc = correct / total
    print(f"[backend] YES/NO accuracy on held-out prompts: {acc:.3f}")

    mgr = CheckpointManager(args.ckpt_dir)
    mgr.save(args.steps, {"params": params},
             extra={"arch": cfg.name, "accuracy": acc})
    print(f"[backend] checkpoint saved to {args.ckpt_dir}")
    return acc


if __name__ == "__main__":
    main()
