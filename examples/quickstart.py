"""Quickstart: the paper's motivating query (Listing 1 / Fig. 1) end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds the hybrid plan, optimizes it three ways (baseline pushdown,
PLOP-Pullup, PLOP-Cost), executes each on the synthetic BookReview
database and prints plans + the LLM-call / relational-row trade-off.
"""
from repro.core import Q, col, optimize
from repro.data import make_bookreview
from repro.data.schemas import BOOKS_ABOUT_AI, REVIEW_POSITIVE
from repro.engine import Executor, result_f1
from repro.semantic import OracleBackend, SemanticRunner


def main():
    db = make_bookreview(seed=0)
    catalog = db.catalog()

    # Listing 1: books about AI with positive reviews, rating >= 3
    plan = (Q.scan("books")
            .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
            .where(col("reviews.rating") >= 3)
            .sem_filter(BOOKS_ABOUT_AI)
            .sem_filter(REVIEW_POSITIVE)
            .select("books.title", "reviews.text")
            .build())

    results = {}
    for strategy in ("none", "pullup", "cost"):
        opt = optimize(plan, catalog, strategy=strategy)
        runner = SemanticRunner(OracleBackend(truths=db.truths))
        table, stats = Executor(db, runner).execute(opt.plan)
        recs = db.materialize(table, ["books.title", "reviews.text"])
        results[strategy] = recs
        label = {"none": "baseline (DuckDB+Cache-style pushdown)",
                 "pullup": "PLOP-Pullup (Alg. 1)",
                 "cost": "PLOP-Cost (Alg. 2 DP)"}[strategy]
        print(f"\n=== {label} ===")
        print(opt.plan.pretty())
        print(f"rows={len(recs)}  LLM calls={stats.llm_calls}  "
              f"cache hits={stats.cache_hits}  "
              f"relational rows={stats.rel_rows}  "
              f"optimizer={opt.total_overhead*1e3:.2f} ms")

    print("\nresult equivalence (Thm 4.1):",
          "F1 pullup vs baseline =",
          result_f1(results["none"], results["pullup"]),
          "| F1 cost vs baseline =",
          result_f1(results["none"], results["cost"]))


if __name__ == "__main__":
    main()
