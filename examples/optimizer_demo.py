"""Optimizer deep-dive: watch the DP cost model change its placement as α
sweeps (paper Fig. 7) on a 6-join TPC-H audit query (Listing 4 analogue).

    PYTHONPATH=src python examples/optimizer_demo.py
"""
from repro.core import CostParams, optimize
from repro.data import make_tpch

import sys
sys.path.insert(0, ".")
from benchmarks.corpus import HYBRID  # noqa: E402


def main():
    spec = next(q for q in HYBRID if q.qid == "Q30")
    db = make_tpch(seed=3)
    catalog = db.catalog()
    plan = spec.build()

    for alpha in (1e-7, 1e-3, 10.0):
        opt = optimize(plan, catalog, strategy="cost",
                       params=CostParams(alpha=alpha))
        print(f"\n=== alpha = {alpha:g} "
              f"(est cost {opt.est_cost:,.1f}, "
              f"{opt.dp_states} DP states, "
              f"{opt.total_overhead*1e3:.1f} ms) ===")
        print(opt.plan.pretty())


if __name__ == "__main__":
    main()
