"""End-to-end driver (the paper's kind = serving): hybrid queries whose
semantic operators are answered by a REAL JAX model served with batched
requests — no oracle in the execution path.

    PYTHONPATH=src python examples/serve_semantic_queries.py

Pipeline: train (or reuse) the 13M-param backend from
examples/train_backend.py -> wrap it in ServingEngine (continuous slot
scheduler: prefill/decode interleaving, mid-decode slot recycling —
docs/serving.md) -> ModelBackend parses YES/NO -> PLOP
optimizes placement -> the executor sends only *distinct uncached* prompts
to the model. Reports accuracy vs. the noise-free oracle plus serving and
cache statistics.
"""
import time

import jax

from repro.core import Q, col, optimize
from repro.data import make_ecommerce
from repro.data.schemas import (
    ECOM_REVIEW_POSITIVE,
    PRODUCT_IS_ELECTRONICS,
)
from repro.engine import Executor, result_f1
from repro.semantic import ModelBackend, OracleBackend, SemanticRunner
from repro.serving.engine import ServingEngine
from repro.sharding import ShardingPolicy
from repro.training.checkpoint import CheckpointManager
from repro.training.data import HashTokenizer

import sys
sys.path.insert(0, "examples")
from train_backend import backend_config  # noqa: E402
from train_backend import main as train_backend_main  # noqa: E402


def get_backend_params():
    mgr = CheckpointManager("artifacts/backend_ckpt")
    if mgr.latest_step() is None:
        print("[serve] no backend checkpoint — training one (300 steps)")
        train_backend_main(["--steps", "300"])
    tree, manifest = mgr.restore()
    print(f"[serve] backend checkpoint: step={manifest['step']} "
          f"trained-accuracy={manifest.get('accuracy'):.3f}")
    return jax.tree.map(jax.numpy.asarray, tree["params"])


def main():
    cfg = backend_config()
    params = get_backend_params()
    policy = ShardingPolicy.single()
    tok = HashTokenizer(cfg.vocab_size)
    engine = ServingEngine(cfg, params, policy, tokenizer=tok,
                           batch_size=32, max_seq=48, max_new_tokens=2)
    db = make_ecommerce(seed=4)
    catalog = db.catalog()

    plan = (Q.scan("products")
            .join(Q.scan("previews"), "products.product_id",
                  "previews.product_id")
            .where(col("previews.rating") >= 4)
            .sem_filter(PRODUCT_IS_ELECTRONICS)
            .sem_filter(ECOM_REVIEW_POSITIVE)
            .select("products.title", "previews.review_id")
            .build())

    # oracle reference (ground truth)
    oracle_runner = SemanticRunner(OracleBackend(truths=db.truths))
    ref_table, _ = Executor(db, oracle_runner).execute(plan)
    ref = db.materialize(ref_table, ["products.title", "previews.review_id"])

    for strategy in ("none", "cost"):
        opt = optimize(plan, catalog, strategy=strategy)
        # bucket-aligned chunked dispatch: runner streams distinct misses
        # in multiples of the engine's serving batch
        backend = ModelBackend.from_engine(engine)
        runner = SemanticRunner(backend)
        ex = Executor(db, runner)
        t0 = time.perf_counter()
        table, stats = ex.execute(opt.plan)
        wall = time.perf_counter() - t0
        recs = db.materialize(table, ["products.title",
                                      "previews.review_id"])
        f1 = result_f1(ref, recs)
        print(f"\n=== strategy={strategy} (real model serving) ===")
        print(f"rows={len(recs)} (oracle says {len(ref)})  "
              f"F1 vs oracle={f1:.3f}")
        print(f"distinct model calls={stats.llm_calls}  "
              f"cache hits={stats.cache_hits}  wall={wall:.1f}s")
        print(f"serving: {engine.stats.batches} batches, "
              f"{engine.stats.decode_steps} decode rounds, "
              f"{engine.stats.prefill_tokens} prefill tokens, "
              f"occupancy={engine.stats.occupancy:.2f}")


if __name__ == "__main__":
    main()
